"""Bench-history ledger + CLI front-end for the perf-regression sentinel.

Every bench run (``kernels_bench`` / ``serve_bench``) appends one line of
headline metrics to ``BENCH_HISTORY.jsonl``, keyed by a provenance
fingerprint (backend / impl / quant / attn / pack hashes) so runs from
different configurations never get compared against each other's
baselines.  ``scripts/ci.sh`` then gates with::

    python benchmarks/bench_history.py check \
        --bench BENCH_serve_smoke.json \
        --baseline benchmarks/baselines/serve_smoke.json

Metric *policy* (which metrics gate, exact vs windowed, tolerance) lives
here in code — see ``SERVE_SPECS`` / ``KERNEL_SPECS`` and the semantics
in ``repro.telemetry.regression`` — while baselines store only the
observed windows, so tightening a band never requires regenerating a
baseline.  Timing tolerances are deliberately generous (3x bands):
the sentinel exists to catch order-of-magnitude cliffs (dropped fusion,
accidental dense fallback, host sync in the decode loop) across noisy
CI hosts, not 10% jitter.  Determinism metrics (bytes/token, bits/nnz)
are exact: they are functions of pack geometry, not the host.
"""
from __future__ import annotations

import argparse
import glob
import hashlib
import json
import sys
import time

from repro.telemetry.regression import (MetricSpec, PerfRegressionError,
                                        assert_no_regression,
                                        format_findings)

HISTORY_PATH = "BENCH_HISTORY.jsonl"
BASELINE_DIR = "benchmarks/baselines"

# one-sided timing band: observed may drift to 1/3x (throughput) or 3x
# (latency) of the baseline window edge before the gate trips
_TIMING_TOL = 2.0
# error ceilings move with numerics noise but never by 10x
_ERR_TOL = 9.0


def _spec_timing_lo(key):
    return MetricSpec(key, "lower_better", _TIMING_TOL)


def _spec_timing_hi(key):
    return MetricSpec(key, "higher_better", _TIMING_TOL)


SERVE_SPECS = [
    _spec_timing_hi("single_stream.dense.tok_s"),
    _spec_timing_hi("single_stream.sparse.tok_s"),
    _spec_timing_hi("single_stream.sparse_attn_int4.tok_s"),
    _spec_timing_hi("batched.sparse.tok_s"),
    _spec_timing_lo("single_stream.sparse.ttft_p95_s"),
    _spec_timing_lo("single_stream.sparse.tpot_p95_s"),
    MetricSpec("single_stream.sparse.bytes_per_token", "exact"),
    MetricSpec("single_stream.sparse_int8.bytes_per_token", "exact"),
    MetricSpec("single_stream.sparse_int4.bytes_per_token", "exact"),
    MetricSpec("single_stream.sparse_attn.bytes_per_token", "exact"),
    MetricSpec("single_stream.sparse_attn_int8.bytes_per_token", "exact"),
    MetricSpec("single_stream.sparse_attn_int4.bytes_per_token", "exact"),
    MetricSpec("pad_frac", "exact", 1e-6),
]

KERNEL_SPECS = [
    _spec_timing_lo("fused_layer_us"),
    _spec_timing_lo("dense_layer_us"),
    _spec_timing_lo("quant.int8.fused_layer_us"),
    _spec_timing_lo("quant.int4.fused_layer_us"),
    _spec_timing_lo("attn_sparse.sparse_step_us"),
    MetricSpec("quant.int8.bytes_per_token", "exact"),
    MetricSpec("quant.int4.bytes_per_token", "exact"),
    MetricSpec("quant.int8.bits_per_nnz", "exact"),
    MetricSpec("quant.int4.bits_per_nnz", "exact"),
    MetricSpec("attn_sparse.bytes_per_token", "exact"),
    MetricSpec("max_rel_err", "lower_better", _ERR_TOL),
    MetricSpec("quant.int8.max_rel_err", "lower_better", _ERR_TOL),
    MetricSpec("quant.int4.max_rel_err", "lower_better", _ERR_TOL),
    MetricSpec("attn_sparse.max_rel_err", "lower_better", _ERR_TOL),
]


def specs_for(doc: dict) -> list:
    bench = doc.get("bench") or ("kernels" if "smoke_result" in doc
                                 or "unbatched" in doc else None)
    if bench == "serve":
        return SERVE_SPECS
    return KERNEL_SPECS


def fingerprint(doc: dict) -> str:
    """Stable identity of *what ran* — provenance subset, not results —
    so history lines from different configs are never conflated."""
    prov = doc.get("provenance") or {}
    subset = {k: prov.get(k)
              for k in ("backend", "impl", "quant", "attn",
                        "pallas_interpret", "packs", "schedule")}
    subset["bench"] = doc.get("bench", doc.get("schema"))
    subset["smoke"] = bool(doc.get("smoke"))
    blob = json.dumps(subset, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _win(value, lo=None, hi=None):
    if value is None:
        return None
    return {"value": float(value),
            "lo": float(lo if lo is not None else value),
            "hi": float(hi if hi is not None else value)}


def headline_serve(doc: dict) -> dict:
    """Headline metrics from a serve bench doc: per-mode throughput with
    its repeat window (lo=p50 pessimistic edge), latency p95s, and the
    exact bytes/token invariants."""
    out: dict = {}
    for scen_name, scen in doc.get("scenarios", {}).items():
        for mode, m in scen.get("modes", {}).items():
            pre = f"{scen_name}.{mode}"
            tok = m.get("throughput_tok_s")
            if tok is not None:
                out[f"{pre}.tok_s"] = _win(
                    tok, lo=m.get("throughput_p50_tok_s"),
                    hi=m.get("throughput_p95_tok_s", tok))
            for hist in ("ttft_s", "tpot_s"):
                h = m.get(hist) or {}
                if h.get("p95") is not None:
                    out[f"{pre}.{hist[:-2]}_p95_s"] = _win(
                        h["p95"], lo=h.get("p50"), hi=h["p95"])
            if m.get("bytes_per_token") is not None:
                out[f"{pre}.bytes_per_token"] = _win(m["bytes_per_token"])
    pad = (doc.get("telemetry") or {}).get("pad_frac")
    if pad is not None:
        out["pad_frac"] = _win(pad)
    return out


def headline_kernels(doc: dict) -> dict:
    """Headline metrics from a kernels bench doc (smoke_result section);
    timing windows use p50/p95 of the interleaved repeats."""
    res = doc.get("smoke_result") or {}
    out: dict = {}

    def timing(dst, node, stem):
        v = node.get(f"{stem}_us")
        if v is not None:
            out[dst] = _win(v, lo=node.get(f"{stem}_p50_us", v),
                            hi=node.get(f"{stem}_p95_us", v))

    timing("fused_layer_us", res, "fused_layer")
    if res.get("dense_layer_us") is not None:
        out["dense_layer_us"] = _win(res["dense_layer_us"])
    if res.get("max_rel_err") is not None:
        out["max_rel_err"] = _win(res["max_rel_err"])
    for q, node in (res.get("quant") or {}).items():
        timing(f"quant.{q}.fused_layer_us", node, "fused_layer")
        for k in ("bytes_per_token", "bits_per_nnz", "max_rel_err"):
            if node.get(k) is not None:
                out[f"quant.{q}.{k}"] = _win(node[k])
    at = res.get("attn_sparse") or {}
    timing("attn_sparse.sparse_step_us", at, "sparse_step")
    for k in ("bytes_per_token", "max_rel_err"):
        if at.get(k) is not None:
            out[f"attn_sparse.{k}"] = _win(at[k])
    # full (non-smoke) runs carry the sweep summary instead
    summ = doc.get("summary") or {}
    for k in ("min_speedup_at_B_ge_8", "min_int8_speedup_vs_fp",
              "min_pad_frac_bucketed"):
        if summ.get(k) is not None:
            out[f"summary.{k}"] = _win(summ[k])
    return out


def headline(doc: dict) -> dict:
    return (headline_serve(doc) if doc.get("bench") == "serve"
            else headline_kernels(doc))


def append(doc: dict, history_path: str = HISTORY_PATH) -> dict:
    """Append one ledger line for a bench doc; returns the line."""
    line = {
        "t_unix": int(time.time()),
        "bench": doc.get("bench", "kernels"),
        "smoke": bool(doc.get("smoke")),
        "fingerprint": fingerprint(doc),
        "metrics": headline(doc),
    }
    with open(history_path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    return line


def make_baseline(doc: dict) -> dict:
    """A checked-in baseline: the headline windows plus enough metadata
    to tell what it was cut from."""
    return {
        "baseline": True,
        "bench": doc.get("bench", "kernels"),
        "smoke": bool(doc.get("smoke")),
        "fingerprint": fingerprint(doc),
        "metrics": headline(doc),
    }


def check(doc: dict, baseline: dict, *, label: str | None = None) -> list:
    """Gate a bench doc against a baseline; raises PerfRegressionError
    (with the offending metric, baseline window, and observed value in
    the message) on drift.  Returns the findings on success."""
    specs = specs_for(doc)
    obs = headline(doc)
    if baseline.get("fingerprint") not in (None, fingerprint(doc)):
        print(f"note: provenance fingerprint changed "
              f"({baseline['fingerprint']} -> {fingerprint(doc)}); "
              f"comparing anyway — refresh the baseline if intentional",
              file=sys.stderr)
    return assert_no_regression(baseline["metrics"], obs, specs,
                                label=label or doc.get("bench", "bench"))


# ------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("append", help="append a bench doc to the ledger")
    p.add_argument("--bench", required=True)
    p.add_argument("--history", default=HISTORY_PATH)
    p = sub.add_parser("check", help="gate a bench doc against a baseline")
    p.add_argument("--bench", required=True)
    p.add_argument("--baseline", required=True)
    p = sub.add_parser("baseline", help="cut a baseline from a bench doc")
    p.add_argument("--bench", required=True)
    p.add_argument("--out", required=True)
    p = sub.add_parser("history", help="print the ledger")
    p.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args(argv)

    if args.cmd == "history":
        for path in sorted(glob.glob(args.history)):
            with open(path) as f:
                for line in f:
                    rec = json.loads(line)
                    print(f"{rec['t_unix']} {rec['bench']}"
                          f"{' smoke' if rec['smoke'] else ''} "
                          f"{rec['fingerprint']} "
                          f"{len(rec['metrics'])} metrics")
        return 0

    with open(args.bench) as f:
        doc = json.load(f)
    if args.cmd == "append":
        line = append(doc, args.history)
        print(f"appended {len(line['metrics'])} metrics "
              f"({line['fingerprint']}) to {args.history}")
        return 0
    if args.cmd == "baseline":
        base = make_baseline(doc)
        with open(args.out, "w") as f:
            json.dump(base, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote baseline with {len(base['metrics'])} metrics "
              f"to {args.out}")
        return 0
    # check
    with open(args.baseline) as f:
        base = json.load(f)
    try:
        findings = check(doc, base, label=args.bench)
    except PerfRegressionError as e:
        print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print(f"sentinel ok: {len(findings)} gated metric(s) in band "
          f"for {args.bench}")
    print(format_findings(findings))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
