"""Figure 11: isolating ESPIM's optimizations — fine-grained base,
+decoupled prefetch, +switch-conflict reorder, +greedy balance (full),
and the brute-force 16x11 switch."""
from __future__ import annotations

from repro.core.pim_sim import espim_cycles
from repro.core.sdds import ESPIMConfig, schedule_matrix

from benchmarks.common import csv_row, cycles_to_us, workload_matrix

STEPS = [
    ("base_finegrained", dict(prefetch=False, reorder=False, balance=False)),
    ("+prefetch", dict(prefetch=True, reorder=False, balance=False)),
    ("+reorder", dict(prefetch=True, reorder=True, balance=False)),
    ("+balance(full)", dict(prefetch=True, reorder=True, balance=True)),
    ("large_switch", dict(prefetch=True, reorder=True, balance=True,
                          full_switch=True)),
]
LAYERS = ("attention.wq", "feed_forward.w1", "feed_forward.w2")


def run(scale: int | None = None, sparsities=(0.5, 0.7, 0.9)) -> list[str]:
    rows = []
    for s in sparsities:
        base_cycles = None
        for step_name, kw in STEPS:
            total = 0.0
            for layer in LAYERS:
                w, sc = workload_matrix(layer, s)
                sched, _ = schedule_matrix(w, ESPIMConfig(**kw))
                total += espim_cycles(sched, ESPIMConfig(**kw)).cycles * sc
            if base_cycles is None:
                base_cycles = total
            rows.append(csv_row(
                f"fig11/s{int(s*100)}/{step_name}", cycles_to_us(total),
                f"speedup_vs_base={base_cycles/total:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
