"""Table IV: area over conventional DRAM for Newton, ESPIM sparse-only,
and the flexible sparse+dense configuration."""
from __future__ import annotations

from repro.core.energy import area_table
from repro.core.sdds import ESPIMConfig

from benchmarks.common import csv_row


def run(scale: int | None = None) -> list[str]:
    t = area_table(ESPIMConfig())
    rows = [
        csv_row("table4/newton", 0.0,
                f"area_over_dram={t['newton']['total']*100:.1f}%"),
        csv_row("table4/espim_sparse_only", 0.0,
                f"area_over_dram={t['espim_sparse_only']['total']*100:.1f}%;"
                f"over_newton="
                f"{t['espim_over_newton_sparse_only']*100:.1f}%"),
        csv_row("table4/espim_flexible", 0.0,
                f"area_over_dram={t['espim_flexible']['total']*100:.1f}%;"
                f"over_newton={t['espim_over_newton_flexible']*100:.1f}%"),
    ]
    for comp, v in t["espim_sparse_only"].items():
        if comp != "total":
            rows.append(csv_row(f"table4/components/{comp}", 0.0,
                                f"area={v*100:.1f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
