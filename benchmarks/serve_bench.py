"""Serving benchmark: drive the engine with a synthetic Poisson-ish trace
and emit BENCH_serve.json.

Measures, for dense and ESPIM-sparse engines on the quickstart config
(llama7b-espim, reduced), in TWO serving scenarios and along an
``attn=dense|sparse`` dimension — ``sparse*`` rows pack only the MLPs
(the pre-PR5 deployment, attention dense), ``sparse_attn*`` rows serve
the WHOLE decoder layer (fused QKV + O groups, every per-token MV through
the packed kernels) — so the bench answers both "does the format win"
and "does covering attention win over covering the MLPs alone":

* ``single_stream`` (slots=1) — the paper's own deployment: ESPIM is a
  memory-bound MV accelerator and decode at B=1 streams every weight
  plane per token, so this is where the compressed format's bytes
  translate to time.  The headline ``sparse_dense_ratio`` (the
  serving-default encoding, ``cfg.espim_quant``, vs dense) is computed
  here.
* ``batched`` (continuous batching over ``slots`` decode slots, mixed
  prompt/output-length Poisson-ish arrivals) — this repo's serving
  extension; on CPU-ref the batched gather competes with BLAS GEMM
  (DESIGN.md sections 8/9), so its ratio is reported but not the
  headline.

Every sparse mode runs in three value-plane encodings — fp32, int8,
nibble-packed int4 (section 9) — each row carrying the whole-model
weight-side ``bytes_per_token`` it streams (packed value + index planes
PLUS the dense attention bytes an MLP-only deployment still moves).
Mode repeats are INTERLEAVED round-robin so shared-host drift hits every
mode equally (sequential best-of runs measured the host, not the
engine).

Also measured: the chunked-prefill TTFT win (wall clock + jitted-call
counts vs token replay) and paged-vs-contiguous bit-parity at
temperature=0.  Loud warnings fire when the default sparse mode loses to
dense single-stream, when a quantized mode loses to the fp sparse path
it exists to beat, or when whole-layer sparse loses to MLP-only sparse
(covering more projections should never cost throughput).

``--fault-drill`` runs the ``serve/faults`` drill instead (one engine
per fault class vs a no-fault baseline — bit flips rejected at load,
quarantine -> dense degradation, cancel/OOM/latency/transient recovery)
and emits its per-class goodput / recovery / leak report; full
(non-smoke) serving runs also attach the drill under ``fault_drill`` in
BENCH_serve.json.  Either path asserts ``check_drill`` — the bench fails
loudly if any fault class could have produced a silent wrong token.

``--overload`` runs the DESIGN.md §13 overload scenario instead: a
seeded Poisson burst at 2x (and, full runs, 4x) the engine's service
rate against a deliberately tight arena and bounded wait queue,
reporting goodput-under-SLO, shed/preempt counts and the terminal-state
census per shed policy — asserted against ``check_overload_drill`` (the
burst must be absorbed by policy: zero failed, zero leaked, drained).
``--crash-drill`` kills an engine at an arbitrary step, restores the
snapshot into a fresh engine and asserts bit-exact output parity plus
zero leaked blocks (``check_crash_drill``).  Full serving runs attach
both reports under ``overload`` / ``crash_drill`` in BENCH_serve.json.

Run:      PYTHONPATH=src:. python benchmarks/serve_bench.py [--smoke]
Drill:    PYTHONPATH=src:. python benchmarks/serve_bench.py --fault-drill [--smoke]
Overload: PYTHONPATH=src:. python benchmarks/serve_bench.py --overload [--smoke]
Crash:    PYTHONPATH=src:. python benchmarks/serve_bench.py --crash-drill [--smoke]
Smoke: tiny traces + schema assertion (wired into scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.sparse_model import sparse_stats, sparsify_model
from repro.kernels import ops
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import (check_crash_drill, check_drill,
                                check_overload_drill, run_crash_drill,
                                run_fault_drill, run_overload_drill)
from repro.telemetry import flightrec, timeline
from repro.telemetry.metrics import (THROUGHPUT_BUCKETS, Histogram,
                                     validate_snapshot)
from repro.telemetry.trace import (BREAKDOWN_SCHEMA_KEYS, Tracer,
                                   phase_breakdown, span_coverage)

from benchmarks import bench_history

ARCH = "llama7b-espim"
SPARSITY = 0.9
QUANT_MODES = ("int8", "int4")
# the attn dimension: "" = MLP-only packs (attention dense), "_attn" =
# whole-layer packs (fused QKV + O groups)
ATTN_MODES = (("", "mlp", "dense"), ("_attn", "all", "sparse"))
SPARSE_MODES = tuple(f"sparse{a}{q}" for a, _, _ in ATTN_MODES
                     for q in ("", "_int8", "_int4"))
# the schedule every serving mode row runs under (PR 10): the default
# chunking — deterministic pack bytes for the exact sentinel specs —
# with the act(gate)·up epilogue fused into the gate+up SpMV launch
# (bit-identical to the unfused reference; tests/test_autotune.py)
SERVE_SCHEDULE = {"source": "default", "tuned": False, "epilogue": "glu"}


def make_trace(rng, n_requests, prompt_lens, out_lens, mean_gap_steps):
    """[(arrival_step, prompt, max_new)] — exponential inter-arrival gaps
    (Poisson process in engine-step time), mixed prompt/output lengths."""
    trace, step = [], 0
    for rid in range(n_requests):
        plen = int(rng.choice(prompt_lens))
        out = int(rng.choice(out_lens))
        prompt = rng.integers(1, 400, size=plen).tolist()
        trace.append((step, prompt, out))
        step += int(rng.exponential(mean_gap_steps))
    return trace


def drive(eng, trace):
    """Submit requests on their arrival step; run to drain.  If the engine
    drains before the next arrival, the step clock fast-forwards to it
    (idle ticks are free no-ops and never advance ``stats.steps``)."""
    reqs = [Request(rid=rid, prompt=p, max_new_tokens=o)
            for rid, (_, p, o) in enumerate(trace)]
    due = {rid: s for rid, (s, _, _) in enumerate(trace)}
    submitted = 0
    t0 = time.monotonic()
    while submitted < len(reqs) or eng.scheduler.has_pending or any(
            s is not None for s in eng.slots):
        while submitted < len(reqs) and due[submitted] <= eng.stats.steps:
            eng.submit(reqs[submitted])
            submitted += 1
        if (submitted < len(reqs) and not eng.scheduler.has_pending
                and all(s is None for s in eng.slots)):
            eng.submit(reqs[submitted])        # fast-forward idle time
            submitted += 1
        eng.step()
    dt = time.monotonic() - t0
    return reqs, dt


def bench_mode(cfg, params, trace, *, sparse=None, slots, max_len,
               block_size, chunk, paged=True, repeats=3):
    """Single-engine best-of run (used for the paged-parity token check)."""
    res, toks = bench_many(cfg, params, trace, sparse_by_mode={"m": sparse},
                           slots=slots, max_len=max_len,
                           block_size=block_size, chunk=chunk, paged=paged,
                           repeats=repeats)
    return res["m"], toks["m"]


def bench_many(cfg, params, trace, *, sparse_by_mode: dict, slots, max_len,
               block_size, chunk, paged=True, repeats=5):
    """Drive the trace ``repeats`` times per mode with the repeats
    INTERLEAVED round-robin across the warmed engines, keeping each
    mode's best run.  Sequential per-mode best-of runs let minutes-scale
    host drift land entirely on one mode; interleaving spreads it evenly,
    so the mode *ratios* are trustworthy even on a noisy shared host."""
    engines, best, toks = {}, {}, {}
    # cross-repeat throughput distribution per mode: the telemetry
    # histogram replaces the bare best-of loop, so every mode reports
    # p50/p95 next to the historic best figure (additive fields)
    tp_hist = {label: Histogram("serve_throughput_tok_s", {},
                                edges=THROUGHPUT_BUCKETS)
               for label in sparse_by_mode}
    for label, sparse in sparse_by_mode.items():
        eng = ServeEngine(cfg, params, batch_slots=slots, max_len=max_len,
                          sparse=sparse, paged=paged, block_size=block_size,
                          prefill_chunk=chunk)
        # warm the jits so the trace measures steady-state serving
        eng.submit(Request(rid=-1, prompt=[1] * (chunk + 2),
                           max_new_tokens=2))
        eng.run()
        engines[label] = eng
    for _ in range(repeats):
        for label, eng in engines.items():
            eng.reset_stats()
            reqs, dt = drive(eng, trace)
            lat = eng.stats.latency_summary()
            tp = eng.stats.tokens_generated / max(dt, 1e-9)
            tp_hist[label].observe(tp)
            res = {
                "throughput_tok_s": tp,
                "tokens": eng.stats.tokens_generated,
                "requests": eng.stats.requests_completed,
                "engine_steps": eng.stats.steps,
                "prefill_chunks": eng.stats.prefill_chunks,
                "decode_steps": eng.stats.decode_steps,
                "slot_occupancy": eng.stats.slot_occupancy,
                "ttft_s": lat["ttft_s"],
                "tpot_s": lat["tpot_s"],
                "queue_delay_s": lat["queue_delay_s"],
                "wall_s": dt,
                "repeats": repeats,
            }
            if (label not in best
                    or res["throughput_tok_s"]
                    > best[label]["throughput_tok_s"]):
                best[label] = res
                toks[label] = [r.output for r in reqs]
    for label, h in tp_hist.items():
        s = h.percentile_summary()
        best[label]["throughput_p50_tok_s"] = s["p50"]
        best[label]["throughput_p95_tok_s"] = s["p95"]
    return best, toks


def bench_ttft(cfg, params, prompt_len, chunk, max_len):
    """Single long-prompt request: chunked prefill vs token replay."""
    out = {}
    for mode in ("chunked", "replay"):
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=max_len,
                          prefill_chunk=chunk, prefill_mode=mode)
        warm = Request(rid=-1, prompt=[1] * min(prompt_len, chunk + 2),
                       max_new_tokens=2)
        eng.submit(warm)
        eng.run()
        eng.reset_stats()
        req = Request(rid=0, prompt=list(range(1, prompt_len + 1)),
                      max_new_tokens=4)
        eng.submit(req)
        eng.run()
        m = eng.scheduler.completed[-1]
        calls_to_first = (eng.stats.prefill_chunks if mode == "chunked"
                          else prompt_len)
        out[mode] = {"ttft_s": m.ttft, "jitted_calls_to_first_token":
                     calls_to_first,
                     "total_engine_steps": eng.stats.steps}
    out["prompt_len"] = prompt_len
    out["chunk"] = chunk
    out["speedup"] = out["replay"]["ttft_s"] / max(out["chunked"]["ttft_s"],
                                                   1e-9)
    out["call_reduction"] = (out["replay"]["jitted_calls_to_first_token"]
                             / out["chunked"]["jitted_calls_to_first_token"])
    return out


def traced_run(cfg, params, sparse, *, slots, max_len, block_size, chunk,
               quant, attn, trace_path=None, seed=0) -> dict:
    """Dedicated short traced run for the ``breakdown`` section.

    Always run separately from the timing engines: the tracer's span
    fencing serializes host/device overlap (by design — exact per-phase
    attribution), which would perturb the throughput figures.  Emits the
    per-step phase breakdown (same BREAKDOWN_SCHEMA_KEYS section
    kernels_bench writes), asserts >= 95% engine.step coverage with zero
    sibling overlaps, validates the metrics snapshot against
    REQUIRED_SERVE_METRICS, and — with ``trace_path`` — writes the
    Perfetto/Chrome trace (or a JSONL event log for ``*.jsonl`` paths).
    """
    rng = np.random.default_rng(seed)
    tr = Tracer(enabled=True)
    eng = ServeEngine(cfg, params, batch_slots=slots, max_len=max_len,
                      sparse=sparse, block_size=block_size,
                      prefill_chunk=chunk, tracer=tr)
    drive(eng, make_trace(rng, 3, [5, 9], [4, 6], 0))
    breakdown = phase_breakdown(tr, parent="engine.step")
    cov = span_coverage(tr.spans(), "engine.step")
    snap = eng.metrics.snapshot()
    validate_snapshot(snap, sparse=sparse is not None)
    # per-request timeline reconstruction (DESIGN.md §14): every terminal
    # request must fold back into a complete queued -> terminal lifecycle
    # whose TTFT/TPOT agree with the engine's own RequestMetrics
    tls = timeline.timelines_from_tracer(tr)
    tl_report = timeline.check_timelines(
        tls, {m.rid: m for m in eng.scheduler.completed})
    pad_gauges = [v for k, v in snap.items()
                  if k.startswith("espim_pad_frac")
                  and isinstance(v, (int, float))]
    prov = ops.provenance(impl="ref", quant=quant, attn=attn)
    if trace_path:
        if trace_path.endswith(".jsonl"):
            tr.write_jsonl(trace_path, provenance=prov)
        else:
            tr.write_chrome_trace(trace_path, provenance=prov)
    return {
        "breakdown": breakdown,
        "step_coverage": round(cov["coverage"], 4),
        "overlap_errors": len(cov["overlap_errors"]),
        "steps_traced": cov["parents"],
        "spans": len(tr.spans()),
        "metrics_families": sorted({k.split("{", 1)[0] for k in snap}),
        "timelines": tl_report,
        "pad_frac": max(pad_gauges) if pad_gauges else None,
        "trace_path": trace_path,
    }


def bench_fault_drill(cfg, params, *, smoke: bool, seed: int,
                      tracer=None) -> dict:
    """The serve/faults drill at bench scale: fp whole-layer packs carry
    the runtime faults, an int8 copy aims the value-plane bit flip at the
    quantized codes.  Returns the drill report plus the pack fingerprints
    it ran against (the provenance that binds a drill result to the exact
    planes it exercised)."""
    sparse = sparsify_model(cfg, params, SPARSITY, projections="all")
    sparse_q = sparsify_model(cfg, params, SPARSITY, projections="all",
                              quant="int8")
    scale = (dict(n_requests=4, max_new_tokens=8) if smoke
             else dict(n_requests=8, max_new_tokens=16))
    drill = run_fault_drill(cfg, params, sparse, sparse_alt=sparse_q,
                            seed=seed, batch_slots=2, max_len=64,
                            block_size=8, prefill_chunk=8, tracer=tracer,
                            **scale)
    drill["packs"] = {"fp": sparse["fingerprint"],
                      "int8": sparse_q["fingerprint"]}
    check_drill(drill)
    return drill


def bench_overload(cfg, params, *, smoke: bool, seed: int,
                   tracer=None) -> dict:
    """The §13 overload scenario at bench scale: Poisson bursts against
    the serving-default whole-layer packs, one run per (burst factor x
    shed policy) cell, each asserted against ``check_overload_drill``."""
    sparse = sparsify_model(cfg, params, SPARSITY, projections="all")
    factors = (2.0,) if smoke else (2.0, 4.0)
    policies = ("shed-largest",) if smoke else ("shed-largest", "reject")
    n_requests = 16 if smoke else 32
    runs = {}
    for factor in factors:
        for policy in policies:
            r = run_overload_drill(
                cfg, params, sparse, seed=seed, factor=factor,
                shed_policy=policy, n_requests=n_requests, tracer=tracer)
            check_overload_drill(r)
            runs[f"{factor:g}x_{policy}"] = r
    return {"pack": sparse["fingerprint"], "runs": runs}


def bench_autotune(cfg, params, *, b: int = 1,
                   max_candidates: int = 3) -> dict:
    """Schedule autotuning on the serving model's own layer-0 gate
    matrix (magnitude-pruned at the serving sparsity): one measured
    search, one warm re-tune that must be a pure cache hit (zero
    candidate benchmarks — the warm-``pack_to_device`` contract), and
    the tuned schedule timed against the hand-picked default at the
    single-stream batch width."""
    import jax.numpy as jnp

    from repro.autotune import (PlanCache, autotune_pack,
                                reset_search_stats, search_stats)
    from repro.core.pruning import magnitude_prune
    from repro.core.sparse_format import chunk_pack, pack_ell
    from repro.telemetry.profile import time_launch

    w = magnitude_prune(
        np.asarray(params["layers"]["mlp"]["w_gate"][0], np.float32).T,
        SPARSITY)
    pack = pack_ell(w)
    cache = PlanCache()
    reset_search_stats()
    plan = autotune_pack(pack, b=b, cache=cache,
                         max_candidates=max_candidates)
    searched_benchmarks = search_stats["benchmarks"]
    plan2 = autotune_pack(pack, b=b, cache=cache,
                          max_candidates=max_candidates)
    cached_benchmarks = search_stats["benchmarks"] - searched_benchmarks

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((pack.n_cols, b)), jnp.float32)

    def best_us(chunk_cols, schedule):
        cp = chunk_pack(pack, chunk_cols)
        vals = jnp.asarray(cp.values)
        cols = jnp.asarray(cp.cols, jnp.int32)

        def fn():
            return ops.espim_spmv_batched(vals, cols, x,
                                          chunk_cols=cp.chunk_cols,
                                          impl="ref", schedule=schedule)
        return time_launch(fn, iters=3, warmup=1,
                           label=f"autotune.serve.{chunk_cols}").best_us

    default_us = best_us(ops.DEFAULT_CHUNK_COLS, None)
    tuned_us = best_us(plan.schedule.chunk_cols, plan.schedule)
    reset_search_stats()
    return {
        "shape": list(w.shape),
        "sparsity": SPARSITY,
        "b": b,
        "plan": plan.to_provenance(),
        "cached_plan": plan2.to_provenance(),
        "cache_hit": plan2.source == "cache",
        "searched_benchmarks": searched_benchmarks,
        "cached_benchmarks": cached_benchmarks,
        "default_us": round(default_us, 1),
        "tuned_us": round(tuned_us, 1),
        "speedup_vs_default": round(default_us / max(tuned_us, 1e-9), 3),
    }


def bench_crash(cfg, params, *, smoke: bool, seed: int,
                tracer=None) -> dict:
    """Kill/restore drill at bench scale: one random kill point per
    seed (full runs sweep three seeds so early/mid/late boundaries are
    all exercised), each asserted bit-exact with zero leaks."""
    sparse = sparsify_model(cfg, params, SPARSITY, projections="all")
    seeds = (seed,) if smoke else (seed, seed + 1, seed + 2)
    runs = {}
    for s in seeds:
        r = run_crash_drill(cfg, params, sparse, seed=s, tracer=tracer)
        check_crash_drill(r)
        runs[str(s)] = r
    return {"pack": sparse["fingerprint"], "runs": runs}


def check_schema(doc: dict) -> None:
    assert doc["paged_parity"] is True, "paged/contiguous tokens diverged"
    for scen_name in ("single_stream", "batched"):
        scen = doc["scenarios"][scen_name]
        for mode in ("dense",) + SPARSE_MODES:
            m = scen["modes"][mode]
            for k in ("throughput_tok_s", "tokens", "requests", "ttft_s",
                      "tpot_s", "queue_delay_s", "slot_occupancy", "attn",
                      "throughput_p50_tok_s", "throughput_p95_tok_s"):
                assert k in m, f"{scen_name}.{mode}.{k} missing"
            assert m["ttft_s"]["p50"] is not None
            assert m["attn"] == ("sparse" if "_attn" in mode else "dense")
            if mode != "dense":
                assert "bytes_per_token" in m and "bits_per_nnz" in m, mode
                assert m["schedule"]["epilogue"] == "glu", mode
        # quantization must shrink the weight bytes a decode token streams
        for a in ("", "_attn"):
            assert (scen["modes"][f"sparse{a}_int4"]["bytes_per_token"]
                    < scen["modes"][f"sparse{a}_int8"]["bytes_per_token"]
                    < scen["modes"][f"sparse{a}"]["bytes_per_token"])
        # packing q/k/v/o must strictly shrink whole-model bytes/token vs
        # leaving attention dense (the acceptance criterion of PR 5)
        for q in ("", "_int8", "_int4"):
            assert (scen["modes"][f"sparse_attn{q}"]["bytes_per_token"]
                    < scen["modes"][f"sparse{q}"]["bytes_per_token"]), q
        assert scen["sparse_dense_ratio"] > 0
        assert scen["sparse_fp_dense_ratio"] > 0
        for mode in QUANT_MODES:
            assert scen["quant_vs_fp"][mode] > 0
        for mode in ("fp",) + QUANT_MODES:
            assert scen["attn_sparse_vs_mlp_only"][mode] > 0
    assert doc["modes"] is doc["scenarios"]["single_stream"]["modes"]
    assert "provenance" in doc and "quant" in doc["provenance"]
    assert doc["provenance"]["attn"] == "sweep"
    assert doc["provenance"]["packs"], "pack fingerprints missing"
    assert doc["provenance"]["schedule"]["epilogue"] == "glu"
    if "fault_drill" in doc:
        assert set(doc["fault_drill"]["faults"]), "empty fault drill"
    if "overload" in doc:
        for name, r in doc["overload"]["runs"].items():
            assert r["leaked_blocks"] == 0, f"overload.{name} leaked"
            assert "goodput_tok_s_under_slo" in r, name
    if "crash_drill" in doc:
        for name, r in doc["crash_drill"]["runs"].items():
            assert r["exact_parity"], f"crash_drill.{name} parity"
    # the traced-run telemetry section (PR 7): per-phase breakdown in the
    # shared schema, >= 95% of engine.step wall accounted to phase spans
    tel = doc["telemetry"]
    for k in BREAKDOWN_SCHEMA_KEYS:
        assert k in tel["breakdown"], f"telemetry.breakdown.{k} missing"
    assert tel["step_coverage"] >= 0.95, \
        f"engine.step span coverage {tel['step_coverage']} < 0.95"
    assert tel["overlap_errors"] == 0, "sibling phase spans overlap"
    # per-request timelines (PR 9): 100% of terminal requests reconstruct
    tl = tel["timelines"]
    assert tl["requests"] > 0, "traced run produced no timelines"
    assert tl["complete"] == tl["requests"], \
        f"only {tl['complete']}/{tl['requests']} timelines complete"
    assert doc["breakdown"] is tel["breakdown"]
    assert doc["sparse_dense_ratio"] > 0
    t = doc["ttft_improvement"]
    for k in ("prompt_len", "chunk", "speedup", "call_reduction",
              "chunked", "replay"):
        assert k in t, f"ttft_improvement.{k} missing"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace + JSON schema assertion (CI)")
    ap.add_argument("--fault-drill", action="store_true",
                    help="run only the fault-injection drill and emit its "
                    "per-fault-class report (goodput, recovery, leaks)")
    ap.add_argument("--overload", action="store_true",
                    help="run only the overload scenario (Poisson burst at "
                    "2-4x capacity: goodput-under-SLO, sheds, preempts)")
    ap.add_argument("--crash-drill", action="store_true",
                    help="run only the snapshot/restore crash drill "
                    "(kill at a random step, restore, assert parity)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the dedicated traced run's span trace: "
                    "Perfetto/Chrome trace_event JSON (open in "
                    "https://ui.perfetto.dev), or a JSONL event log when "
                    "PATH ends in .jsonl")
    args = ap.parse_args()

    # benches opt the process flight recorder into autodump: any fault
    # ladder trip during the run (quarantine, storm, crash drill) leaves
    # a FLIGHT_*.json post-mortem next to the bench JSON
    flight = flightrec.FlightRecorder(capacity=4096, autodump=True)
    flightrec.set_recorder(flight)

    if (args.trace is None and not args.smoke and not args.fault_drill
            and not args.overload and not args.crash_drill):
        # full serving runs always leave the trace artifact behind, the
        # way the CI smokes already do
        args.trace = "TRACE_serve.json"

    rng = np.random.default_rng(args.seed)
    cfg = get_config(ARCH, reduced=True)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))

    if args.fault_drill:
        drill_tracer = Tracer(enabled=True) if args.trace else None
        drill = bench_fault_drill(cfg, params, smoke=args.smoke,
                                  seed=args.seed, tracer=drill_tracer)
        prov = ops.provenance(impl="ref", quant="sweep", attn="sparse",
                              packs=drill["packs"])
        doc = {
            "bench": "serve_fault_drill",
            "arch": ARCH,
            "reduced": True,
            "smoke": args.smoke,
            "sparsity": SPARSITY,
            "provenance": prov,
            "fault_drill": drill,
        }
        if drill_tracer is not None:
            if args.trace.endswith(".jsonl"):
                drill_tracer.write_jsonl(args.trace, provenance=prov)
            else:
                drill_tracer.write_chrome_trace(args.trace, provenance=prov)
            doc["breakdown"] = phase_breakdown(drill_tracer,
                                               parent="engine.step")
            doc["trace_path"] = args.trace
        out = (args.out if args.out != "BENCH_serve.json"
               else "BENCH_fault_drill.json")
        doc["flight_dumps"] = flight.dumps
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
        f_ = drill["faults"]
        print(f"wrote {out}: all {len(f_)} fault classes within contract "
              f"(load faults rejected: "
              f"{sum(r.get('rejected_at_load', False) for r in f_.values())}"
              f"; nonfinite quarantines "
              f"{f_['nonfinite_logits']['quarantines']}, degraded-token "
              f"fraction {f_['nonfinite_logits']['degraded_token_fraction']:.2f}"
              f"; retries {f_['transient_step_error']['retries']}; watchdog "
              f"flags {f_['latency_spike']['watchdog_flags']}; leaked blocks "
              f"{max(r.get('leaked_blocks', 0) for r in f_.values())})")
        return

    if args.overload or args.crash_drill:
        doc = {
            "bench": "serve_overload" if args.overload else "serve_crash",
            "arch": ARCH,
            "reduced": True,
            "smoke": args.smoke,
            "sparsity": SPARSITY,
            "provenance": ops.provenance(impl="ref", quant="none",
                                         attn="sparse"),
        }
        if args.overload:
            doc["overload"] = bench_overload(cfg, params, smoke=args.smoke,
                                             seed=args.seed)
            default_out = "BENCH_overload.json"
            runs = doc["overload"]["runs"]
            summary = "; ".join(
                f"{name}: {r['sheds']} shed / {r['preempts']} preempted, "
                f"{r['goodput_tok_s_under_slo']:.1f} tok/s under SLO, "
                f"{r['leaked_blocks']} leaked"
                for name, r in runs.items())
        else:
            doc["crash_drill"] = bench_crash(cfg, params, smoke=args.smoke,
                                             seed=args.seed)
            default_out = "BENCH_crash_drill.json"
            runs = doc["crash_drill"]["runs"]
            summary = "; ".join(
                f"seed {name}: kill@{r['kill_step']}/{r['total_steps']}, "
                f"{r['restored_requests']} restored, parity "
                f"{r['exact_parity']}, recovery {r['recovery_s']:.2f}s"
                for name, r in runs.items())
        out = (args.out if args.out != "BENCH_serve.json" else default_out)
        doc["flight_dumps"] = flight.dumps
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {out}: {summary}")
        return

    if args.smoke:
        slots, max_len, block_size, chunk = 2, 64, 8, 8
        trace = make_trace(rng, 4, [4, 9, 17], [3, 5], 2)
        ss_trace = make_trace(rng, 2, [6, 12], [6], 0)
        repeats_ss, repeats_b = 2, 2
        ttft_prompt = 16
    else:
        # batched: decode-weighted mixed-length arrivals (prefill runs the
        # dense GEMMs in every sparse mode — Section III-I — so decode is
        # where the modes differ); single_stream: back-to-back requests on
        # one slot, the paper's B=1 MV deployment
        slots, max_len, block_size, chunk = 4, 192, 16, 32
        trace = make_trace(rng, 12, [8, 24, 64, 120], [24, 32, 48], 2)
        ss_trace = make_trace(rng, 4, [16, 48], [48], 0)
        repeats_ss, repeats_b = 5, 3
        ttft_prompt = 128

    sparses = {"dense": None}
    plane_stats = {}
    for suffix, proj, attn in ATTN_MODES:
        for qlabel, quant in (("", None),
                              *((f"_{m}", m) for m in QUANT_MODES)):
            label = f"sparse{suffix}{qlabel}"
            sp = sparsify_model(cfg, params, SPARSITY, projections=proj,
                                quant=quant)
            sparses[label] = sp
            plane_stats[label] = sparse_stats(sp)["total"]

    def run_scenario(tr, n_slots, repeats):
        res, toks = bench_many(cfg, params, tr, sparse_by_mode=sparses,
                               slots=n_slots, max_len=max_len,
                               block_size=block_size, chunk=chunk,
                               repeats=repeats)
        res["dense"]["attn"] = "dense"
        for label, st in plane_stats.items():
            res[label]["quant"] = sparses[label]["quant"]
            res[label]["attn"] = ("sparse" if sparses[label]["attn_sparse"]
                                  else "dense")
            # which kernel schedule served this row: the engine runs the
            # hand-picked default chunking (deterministic bytes metrics)
            # with the act(gate)·up epilogue fused into the gate+up launch
            res[label]["schedule"] = dict(SERVE_SCHEDULE)
            res[label]["bytes_per_token"] = st["bytes_per_token"]
            res[label]["packed_bytes_per_token"] = st[
                "packed_bytes_per_token"]
            res[label]["bits_per_nnz"] = round(st["bits_per_nnz"], 2)
        dense_tok = max(res["dense"]["throughput_tok_s"], 1e-9)
        fp_tok = max(res["sparse"]["throughput_tok_s"], 1e-9)
        default_mode = ("sparse" if cfg.espim_quant == "none"
                        else f"sparse_{cfg.espim_quant}")
        scen = {
            "slots": n_slots,
            "n_requests": len(tr),
            "repeats": repeats,
            "modes": res,
            "sparse_default_mode": default_mode,
            "sparse_dense_ratio": res[default_mode]["throughput_tok_s"]
            / dense_tok,
            "sparse_fp_dense_ratio": fp_tok / dense_tok,
            "quant_vs_fp": {
                m: res[f"sparse_{m}"]["throughput_tok_s"] / fp_tok
                for m in QUANT_MODES},
            # whole-layer (fused QKV + O) vs MLP-only, per encoding
            "attn_sparse_vs_mlp_only": {
                q or "fp": res[f"sparse_attn{f'_{q}' if q else ''}"]
                ["throughput_tok_s"]
                / max(res[f"sparse{f'_{q}' if q else ''}"]
                      ["throughput_tok_s"], 1e-9)
                for q in ("",) + QUANT_MODES},
        }
        return scen, toks

    single, _ = run_scenario(ss_trace, 1, repeats_ss)
    batched, toks_all = run_scenario(trace, slots, repeats_b)
    _, toks_contig = bench_mode(
        cfg, params, trace, slots=slots, max_len=max_len,
        block_size=block_size, chunk=chunk, paged=False, repeats=1)
    parity = toks_all["dense"] == toks_contig

    # per-phase breakdown from a dedicated traced run on the serving
    # default mode (never the timing engines — span fencing serializes
    # the overlap the timing runs rely on)
    default_label = ("sparse_attn" if cfg.espim_quant == "none"
                     else f"sparse_attn_{cfg.espim_quant}")
    telemetry = traced_run(
        cfg, params, sparses[default_label], slots=min(slots, 2),
        max_len=max_len, block_size=block_size, chunk=chunk,
        quant=cfg.espim_quant, attn="sparse", trace_path=args.trace,
        seed=args.seed)

    # headline ratios come from the paper's own serving mode (B=1 MV)
    modes = single["modes"]
    default_mode = single["sparse_default_mode"]
    ratio = single["sparse_dense_ratio"]
    fp_tok = modes["sparse"]["throughput_tok_s"]
    doc = {
        "bench": "serve",
        "arch": ARCH,
        "reduced": True,
        "smoke": args.smoke,
        "slots": slots,
        "max_len": max_len,
        "block_size": block_size,
        "prefill_chunk": chunk,
        "n_requests": len(trace),
        "sparsity": SPARSITY,
        "provenance": ops.provenance(
            impl="ref", quant=cfg.espim_quant, attn="sweep",
            packs={label: sp["fingerprint"]
                   for label, sp in sparses.items() if sp is not None},
            schedule=SERVE_SCHEDULE),
        "scenarios": {"single_stream": single, "batched": batched},
        # headline fields = the single_stream (paper B=1 MV) scenario;
        # "modes" kept as its alias for cross-PR continuity
        "modes": modes,
        "sparse_default_mode": default_mode,
        "sparse_dense_ratio": ratio,
        "sparse_fp_dense_ratio": single["sparse_fp_dense_ratio"],
        "quant_vs_fp": single["quant_vs_fp"],
        "attn_sparse_vs_mlp_only": single["attn_sparse_vs_mlp_only"],
        "batched_sparse_dense_ratio": batched["sparse_dense_ratio"],
        "bytes_per_token_reduction": {
            m: (modes["sparse"]["bytes_per_token"]
                / max(1, modes[f"sparse_{m}"]["bytes_per_token"]))
            for m in QUANT_MODES},
        "ttft_improvement": bench_ttft(cfg, params, ttft_prompt, chunk,
                                       max_len),
        "paged_parity": parity,
        "telemetry": telemetry,
        "breakdown": telemetry["breakdown"],
    }
    if not args.smoke:
        # full runs carry the robustness drills inline; CI smoke runs them
        # as their own --fault-drill / --overload / --crash-drill passes
        # instead (kept out of the smoke schema run so each gate fails
        # independently)
        doc["fault_drill"] = bench_fault_drill(cfg, params, smoke=True,
                                               seed=args.seed)
        doc["overload"] = bench_overload(cfg, params, smoke=True,
                                         seed=args.seed)
        doc["crash_drill"] = bench_crash(cfg, params, smoke=True,
                                         seed=args.seed)
        # schedule autotuning on the model's own gate matrix: search once,
        # assert the warm re-tune is a pure cache hit, time tuned vs
        # default (PR 10)
        doc["autotune"] = bench_autotune(cfg, params)
        assert doc["autotune"]["cache_hit"], "warm re-tune missed the cache"
        assert doc["autotune"]["cached_benchmarks"] == 0, \
            "cache hit ran candidate benchmarks"
    doc["flight_dumps"] = flight.dumps
    check_schema(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    hist_line = bench_history.append(doc)
    print(f"appended {len(hist_line['metrics'])} headline metrics "
          f"({hist_line['fingerprint']}) to {bench_history.HISTORY_PATH}")
    t = doc["ttft_improvement"]
    print(f"wrote {args.out}: single-stream dense "
          f"{modes['dense']['throughput_tok_s']:.1f} tok/s, sparse fp "
          f"{fp_tok:.1f}, int8 "
          f"{modes['sparse_int8']['throughput_tok_s']:.1f}, int4 "
          f"{modes['sparse_int4']['throughput_tok_s']:.1f} tok/s "
          f"({default_mode}/dense ratio {ratio:.2f}, batched ratio "
          f"{batched['sparse_dense_ratio']:.2f}; whole-layer fp "
          f"{modes['sparse_attn']['throughput_tok_s']:.1f}, int8 "
          f"{modes['sparse_attn_int8']['throughput_tok_s']:.1f} tok/s; "
          f"bytes/token mlp-only "
          f"{modes['sparse']['bytes_per_token']} -> "
          f"{modes['sparse_int8']['bytes_per_token']} -> "
          f"{modes['sparse_int4']['bytes_per_token']}, whole-layer "
          f"{modes['sparse_attn']['bytes_per_token']} -> "
          f"{modes['sparse_attn_int8']['bytes_per_token']} -> "
          f"{modes['sparse_attn_int4']['bytes_per_token']}); TTFT@"
          f"{t['prompt_len']} chunked {t['chunked']['ttft_s']:.3f}s vs "
          f"replay {t['replay']['ttft_s']:.3f}s "
          f"({t['speedup']:.1f}x wall, {t['call_reduction']:.1f}x fewer "
          f"jitted calls); paged parity: {parity}")
    if ratio < 1.0:
        print(
            "\n" + "!" * 72 + "\n"
            f"!! WARNING: ESPIM-sparse serving ({default_mode}) is SLOWER "
            f"than dense (ratio {ratio:.2f}).\n"
            f"!! The compressed format should never lose the serving race "
            f"it exists to win\n"
            f"!! (paper Sec. I/IV) — check BENCH_kernels.json and the "
            f"provenance block\n"
            f"!! (backend={doc['provenance']['backend']}, "
            f"impl={doc['provenance']['impl']}).\n" + "!" * 72,
            file=sys.stderr)
    for m in QUANT_MODES:
        if doc["quant_vs_fp"][m] < 1.0:
            print(
                "\n" + "!" * 72 + "\n"
                f"!! WARNING: {m}-quantized sparse serving is SLOWER than "
                f"the fp sparse path\n"
                f"!! (ratio {doc['quant_vs_fp'][m]:.2f}) despite streaming "
                f"{doc['bytes_per_token_reduction'][m]:.2f}x fewer weight "
                f"bytes/token.\n"
                f"!! The narrow value plane pays off only where decode is "
                f"bandwidth-bound —\n"
                f"!! on this backend "
                f"(backend={doc['provenance']['backend']}, "
                f"impl={doc['provenance']['impl']}) the dequant\n"
                f"!! arithmetic is winning; see BENCH_kernels.json "
                f"quant rows before shipping {m}.\n" + "!" * 72,
                file=sys.stderr)
    for m, r in doc["attn_sparse_vs_mlp_only"].items():
        if r < 1.0:
            bm = "" if m == "fp" else f"_{m}"
            print(
                "\n" + "!" * 72 + "\n"
                f"!! WARNING: WHOLE-LAYER sparse serving ({m}: fused QKV + "
                f"O packs) is SLOWER\n"
                f"!! than MLP-only sparse (ratio {r:.2f}) despite streaming "
                f"{modes[f'sparse{bm}']['bytes_per_token'] / max(1, modes[f'sparse_attn{bm}']['bytes_per_token']):.2f}x "
                f"fewer weight bytes/token.\n"
                f"!! Packing q/k/v/o should never lose to leaving them "
                f"dense where decode is\n"
                f"!! bandwidth-bound (paper Sec. III: the format is "
                f"projection-agnostic); on this\n"
                f"!! backend (backend={doc['provenance']['backend']}, "
                f"impl={doc['provenance']['impl']}) the attention MVs are\n"
                f"!! too small for the gather to beat GEMM — see "
                f"BENCH_kernels.json before shipping.\n" + "!" * 72,
                file=sys.stderr)


if __name__ == "__main__":
    main()
