"""Serving benchmark: drive the engine with a synthetic Poisson-ish trace
and emit BENCH_serve.json.

Measures, for dense and ESPIM-sparse engines on the quickstart config
(llama7b-espim, reduced):

* steady-state throughput (tok/s) and per-request TTFT / TPOT / queue
  delay p50/p95 under a mixed prompt/output-length arrival trace;
* the chunked-prefill TTFT win: wall-clock and jitted-call counts for a
  prompt_len-P request served via chunked prefill vs token replay
  (ceil(P/chunk) prefill calls vs P decode steps);
* paged-vs-contiguous bit-parity: the block-pool cache must reproduce the
  contiguous engine's sampled tokens exactly at temperature=0.

Run:   PYTHONPATH=src:. python benchmarks/serve_bench.py [--smoke]
Smoke: tiny trace + schema assertion (wired into scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.sparse_model import sparsify_mlps
from repro.kernels import ops
from repro.models import factory
from repro.serve.engine import Request, ServeEngine

ARCH = "llama7b-espim"
SPARSITY = 0.9


def make_trace(rng, n_requests, prompt_lens, out_lens, mean_gap_steps):
    """[(arrival_step, prompt, max_new)] — exponential inter-arrival gaps
    (Poisson process in engine-step time), mixed prompt/output lengths."""
    trace, step = [], 0
    for rid in range(n_requests):
        plen = int(rng.choice(prompt_lens))
        out = int(rng.choice(out_lens))
        prompt = rng.integers(1, 400, size=plen).tolist()
        trace.append((step, prompt, out))
        step += int(rng.exponential(mean_gap_steps))
    return trace


def drive(eng, trace):
    """Submit requests on their arrival step; run to drain.  If the engine
    drains before the next arrival, the step clock fast-forwards to it
    (idle ticks are free no-ops and never advance ``stats.steps``)."""
    reqs = [Request(rid=rid, prompt=p, max_new_tokens=o)
            for rid, (_, p, o) in enumerate(trace)]
    due = {rid: s for rid, (s, _, _) in enumerate(trace)}
    submitted = 0
    t0 = time.monotonic()
    while submitted < len(reqs) or eng.scheduler.has_pending or any(
            s is not None for s in eng.slots):
        while submitted < len(reqs) and due[submitted] <= eng.stats.steps:
            eng.submit(reqs[submitted])
            submitted += 1
        if (submitted < len(reqs) and not eng.scheduler.has_pending
                and all(s is None for s in eng.slots)):
            eng.submit(reqs[submitted])        # fast-forward idle time
            submitted += 1
        eng.step()
    dt = time.monotonic() - t0
    return reqs, dt


def bench_mode(cfg, params, trace, *, sparse=None, slots, max_len,
               block_size, chunk, paged=True, repeats=3):
    """Drive the trace ``repeats`` times on one warmed engine and keep the
    best run — single-shot wall clocks on a shared host are too noisy for
    a steady-state serving number (same best-of discipline as the kernel
    bench's ``_time``)."""
    eng = ServeEngine(cfg, params, batch_slots=slots, max_len=max_len,
                      sparse=sparse, paged=paged, block_size=block_size,
                      prefill_chunk=chunk)
    # warm the jits so the trace measures steady-state serving
    warm = Request(rid=-1, prompt=[1] * (chunk + 2), max_new_tokens=2)
    eng.submit(warm)
    eng.run()

    best, toks = None, None
    for _ in range(repeats):
        eng.reset_stats()
        reqs, dt = drive(eng, trace)
        lat = eng.stats.latency_summary()
        res = {
            "throughput_tok_s": eng.stats.tokens_generated / max(dt, 1e-9),
            "tokens": eng.stats.tokens_generated,
            "requests": eng.stats.requests_completed,
            "engine_steps": eng.stats.steps,
            "prefill_chunks": eng.stats.prefill_chunks,
            "decode_steps": eng.stats.decode_steps,
            "slot_occupancy": eng.stats.slot_occupancy,
            "ttft_s": lat["ttft_s"],
            "tpot_s": lat["tpot_s"],
            "queue_delay_s": lat["queue_delay_s"],
            "wall_s": dt,
            "repeats": repeats,
        }
        if best is None or res["throughput_tok_s"] > best["throughput_tok_s"]:
            best = res
            toks = [r.output for r in reqs]
    return best, toks


def bench_ttft(cfg, params, prompt_len, chunk, max_len):
    """Single long-prompt request: chunked prefill vs token replay."""
    out = {}
    for mode in ("chunked", "replay"):
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=max_len,
                          prefill_chunk=chunk, prefill_mode=mode)
        warm = Request(rid=-1, prompt=[1] * min(prompt_len, chunk + 2),
                       max_new_tokens=2)
        eng.submit(warm)
        eng.run()
        eng.reset_stats()
        req = Request(rid=0, prompt=list(range(1, prompt_len + 1)),
                      max_new_tokens=4)
        eng.submit(req)
        eng.run()
        m = eng.scheduler.completed[-1]
        calls_to_first = (eng.stats.prefill_chunks if mode == "chunked"
                          else prompt_len)
        out[mode] = {"ttft_s": m.ttft, "jitted_calls_to_first_token":
                     calls_to_first,
                     "total_engine_steps": eng.stats.steps}
    out["prompt_len"] = prompt_len
    out["chunk"] = chunk
    out["speedup"] = out["replay"]["ttft_s"] / max(out["chunked"]["ttft_s"],
                                                   1e-9)
    out["call_reduction"] = (out["replay"]["jitted_calls_to_first_token"]
                             / out["chunked"]["jitted_calls_to_first_token"])
    return out


def check_schema(doc: dict) -> None:
    assert doc["paged_parity"] is True, "paged/contiguous tokens diverged"
    for mode in ("dense", "sparse"):
        m = doc["modes"][mode]
        for k in ("throughput_tok_s", "tokens", "requests", "ttft_s",
                  "tpot_s", "queue_delay_s", "slot_occupancy"):
            assert k in m, f"modes.{mode}.{k} missing"
        assert m["ttft_s"]["p50"] is not None
    assert "provenance" in doc and "backend" in doc["provenance"]
    assert doc["sparse_dense_ratio"] > 0
    t = doc["ttft_improvement"]
    for k in ("prompt_len", "chunk", "speedup", "call_reduction",
              "chunked", "replay"):
        assert k in t, f"ttft_improvement.{k} missing"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace + JSON schema assertion (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    cfg = get_config(ARCH, reduced=True)
    params = factory.init_params(cfg, jax.random.PRNGKey(0))

    if args.smoke:
        slots, max_len, block_size, chunk = 2, 64, 8, 8
        trace = make_trace(rng, 4, [4, 9, 17], [3, 5], 2)
        ttft_prompt = 16
    else:
        slots, max_len, block_size, chunk = 4, 192, 16, 32
        trace = make_trace(rng, 12, [8, 24, 64, 120], [8, 16, 32], 4)
        ttft_prompt = 128

    modes = {}
    modes["dense"], toks_paged = bench_mode(
        cfg, params, trace, slots=slots, max_len=max_len,
        block_size=block_size, chunk=chunk, paged=True)
    _, toks_contig = bench_mode(
        cfg, params, trace, slots=slots, max_len=max_len,
        block_size=block_size, chunk=chunk, paged=False)
    parity = toks_paged == toks_contig

    sparse = sparsify_mlps(cfg, params, SPARSITY)
    modes["sparse"], _ = bench_mode(
        cfg, params, trace, sparse=sparse, slots=slots, max_len=max_len,
        block_size=block_size, chunk=chunk, paged=True)

    ratio = (modes["sparse"]["throughput_tok_s"]
             / max(modes["dense"]["throughput_tok_s"], 1e-9))
    doc = {
        "bench": "serve",
        "arch": ARCH,
        "reduced": True,
        "smoke": args.smoke,
        "slots": slots,
        "max_len": max_len,
        "block_size": block_size,
        "prefill_chunk": chunk,
        "n_requests": len(trace),
        "sparsity": SPARSITY,
        "provenance": ops.provenance(impl="ref"),
        "modes": modes,
        "sparse_dense_ratio": ratio,
        "ttft_improvement": bench_ttft(cfg, params, ttft_prompt, chunk,
                                       max_len),
        "paged_parity": parity,
    }
    check_schema(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    t = doc["ttft_improvement"]
    print(f"wrote {args.out}: dense "
          f"{modes['dense']['throughput_tok_s']:.1f} tok/s, sparse "
          f"{modes['sparse']['throughput_tok_s']:.1f} tok/s "
          f"(ratio {ratio:.2f}); TTFT@"
          f"{t['prompt_len']} chunked {t['chunked']['ttft_s']:.3f}s vs "
          f"replay {t['replay']['ttft_s']:.3f}s "
          f"({t['speedup']:.1f}x wall, {t['call_reduction']:.1f}x fewer "
          f"jitted calls); paged parity: {parity}")
    if ratio < 1.0:
        print(
            "\n" + "!" * 72 + "\n"
            f"!! WARNING: ESPIM-sparse serving is SLOWER than dense "
            f"(ratio {ratio:.2f}).\n"
            f"!! The compressed format should never lose the serving race "
            f"it exists to win\n"
            f"!! (paper Sec. I/IV) — check BENCH_kernels.json and the "
            f"provenance block\n"
            f"!! (backend={doc['provenance']['backend']}, "
            f"impl={doc['provenance']['impl']}).\n" + "!" * 72,
            file=sys.stderr)


if __name__ == "__main__":
    main()
