"""The paper's deployment scenario: serve an LM whose projections were
magnitude-pruned and packed into the ESPIM format, through the production
serving stack — paged KV cache, chunked prefill, and a latency-aware
scheduler — and compare the sparse projections' outputs against the
dense-pruned reference.

``--quant {none,int8,int4}`` (default: the config's serving preset,
int8 for llama7b-espim) re-encodes the packs' value planes (DESIGN.md
section 9) and prints the measured weight-bytes/token reduction.

Run:  PYTHONPATH=src python examples/serve_sparse_llm.py [--quant int4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.espim_linear import ESPIMLinear
from repro.core.pruning import magnitude_prune
from repro.core.sparse_model import sparse_stats, sparsify_mlps
from repro.models import factory
from repro.serve.engine import Request, ServeEngine

SPARSITY = 0.9

cfg = get_config("llama7b-espim", reduced=True)
ap = argparse.ArgumentParser()
ap.add_argument("--quant", choices=("none", "int8", "int4"),
                default=cfg.espim_quant,
                help="value-plane encoding for the packed MLPs "
                     f"(default: the config preset, {cfg.espim_quant})")
QUANT = ap.parse_args().quant
params = factory.init_params(cfg, jax.random.PRNGKey(0))

# --- flexible dense/sparse projections (Section III-I) ---------------------
# Pack every attention projection of layer 0 through ESPIMLinear and verify
# against the dense-pruned reference.
print(f"packing layer-0 projections at {SPARSITY:.0%} sparsity:")
rng = np.random.default_rng(0)
for name in ("wq", "wk", "wv", "wo"):
    w = np.asarray(params["layers"]["attn"][name][0], np.float32).T
    lin = ESPIMLinear.from_dense(w, prune_sparsity=SPARSITY)
    x = rng.standard_normal(w.shape[1]).astype(np.float32)
    y = np.asarray(lin(jnp.asarray(x), impl="ref"))
    ref = magnitude_prune(w, SPARSITY) @ x
    print(f"  {name}: sparse path={lin.sparse}, "
          f"max err vs dense-pruned = {np.abs(y - ref).max():.2e}")

# --- production serving: paged cache + chunked prefill + scheduler ---------
# A mixed-length trace: short chat-like prompts interleaved with long ones.
# The shortest-prompt-first policy admits the short prompts ahead of the
# long ones (lower mean TTFT); chunked prefill turns each long prompt into
# ceil(len/chunk) jitted calls; all slots share one block-pool KV arena.
# ``--quant`` serves decode from int8/int4 value planes (section 9): same
# packs, same schedules, narrow codes + per-row-group scales.
sparse = sparsify_mlps(cfg, params, SPARSITY, quant=QUANT)
if QUANT != "none":
    st = sparse_stats(sparse)["total"]
    # the fp baseline needs no second packing pass: fp32 values cost 4
    # bytes/slot — exactly the quant-invariant int32 index plane's size
    fp_bytes = 2 * st["index_plane_bytes"]
    fp_bits = 8.0 * st["index_plane_bytes"] / st["nnz"]
    print(f"\nquant={QUANT}: weight bytes/token "
          f"{fp_bytes} -> {st['bytes_per_token']} "
          f"({fp_bytes / st['bytes_per_token']:.2f}x smaller; value plane "
          f"{st['bits_per_nnz']:.1f} bits/nnz vs fp {fp_bits:.1f})")
prompt_lens = [3, 40, 2, 56, 5, 24, 4, 12]
prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
           for n in prompt_lens]

eng = ServeEngine(cfg, params, batch_slots=4, max_len=96, sparse=sparse,
                  paged=True, block_size=16, prefill_chunk=16,
                  policy="sjf")
reqs = [Request(rid=rid, prompt=p, max_new_tokens=12)
        for rid, p in enumerate(prompts)]
for r in reqs:
    eng.submit(r)
t0 = time.time()
stats = eng.run()
dt = time.time() - t0
lat = stats.latency_summary()
print(f"\nserved {stats.requests_completed} requests / "
      f"{stats.tokens_generated} tokens in {dt:.1f}s "
      f"({stats.tokens_generated / dt:.1f} tok/s on CPU; "
      f"{stats.prefill_chunks} prefill chunks + {stats.decode_steps} "
      f"decode steps, slot occupancy {stats.slot_occupancy:.0%})")
print(f"TTFT p50/p95 = {lat['ttft_s']['p50']:.3f}/"
      f"{lat['ttft_s']['p95']:.3f}s, "
      f"TPOT p50 = {lat['tpot_s']['p50'] * 1e3:.1f}ms, "
      f"queue delay p95 = {lat['queue_delay_s']['p95']:.3f}s "
      f"(sjf over {len(reqs)} mixed-length prompts, "
      f"arena {eng.cache.num_blocks} x {eng.cache.block_size}-token "
      f"blocks)")
