"""The paper's deployment scenario: serve an LM whose projections were
magnitude-pruned and packed into the ESPIM format, through the production
serving stack — paged KV cache, chunked prefill, and a latency-aware
scheduler — and compare the sparse projections' outputs against the
dense-pruned reference.

``--quant {none,int8,int4}`` (default: the config's serving preset,
int8 for llama7b-espim) re-encodes the packs' value planes (DESIGN.md
section 9) and prints the measured weight-bytes/token reduction.
``--sparse-attn`` serves the WHOLE decoder layer from the format — the
fused QKV + O pack groups (DESIGN.md section 10) on top of the MLP packs
— and prints the dense-attention vs whole-layer bytes/token delta.
``--trace out.json`` records every engine phase (scheduler / prefill /
decode / host sync) as nested spans and writes a Perfetto/Chrome trace —
open it at https://ui.perfetto.dev — plus a per-phase breakdown on
stdout (DESIGN.md section 12).

Run:  PYTHONPATH=src python examples/serve_sparse_llm.py \
          [--quant int4] [--sparse-attn] [--trace out.json]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.espim_linear import ESPIMGroupLinear
from repro.core.pruning import magnitude_prune
from repro.core.sparse_model import sparse_stats, sparsify_model
from repro.kernels import ops
from repro.models import factory
from repro.serve.engine import Request, ServeEngine
from repro.telemetry.timeline import format_timeline, timelines_from_tracer
from repro.telemetry.trace import Tracer, phase_breakdown

SPARSITY = 0.9

cfg = get_config("llama7b-espim", reduced=True)
ap = argparse.ArgumentParser()
ap.add_argument("--quant", choices=("none", "int8", "int4"),
                default=cfg.espim_quant,
                help="value-plane encoding for the packed projections "
                     f"(default: the config preset, {cfg.espim_quant})")
ap.add_argument("--sparse-attn", action="store_true",
                help="pack q/k/v/o too (fused QKV + O groups) and serve "
                     "every per-token MV from the compressed format")
ap.add_argument("--trace", default=None, metavar="PATH",
                help="write a Perfetto/Chrome trace of the serving run "
                     "(open at https://ui.perfetto.dev); .jsonl paths get "
                     "the plain event-log format instead")
ap.add_argument("--autotune", action="store_true",
                help="tune the SDDS kernel schedule on the model's own "
                     "layer-0 gate matrix (searched, then re-tuned off the "
                     "warm plan cache), serve a second engine under the "
                     "tuned chunking, and print the tok/s delta vs the "
                     "default schedule")
args = ap.parse_args()
QUANT = args.quant
tracer = Tracer(enabled=args.trace is not None)
params = factory.init_params(cfg, jax.random.PRNGKey(0))

# --- flexible dense/sparse projections (Section III-I) ---------------------
# Pack layer 0's q/k/v as ONE fused group (shared balance perm, one SpMV
# launch for all three) and verify each output against its dense-pruned
# reference — the PackGroup contract as a standalone layer.
print(f"packing layer-0 q/k/v as one fused group at {SPARSITY:.0%} "
      f"sparsity:")
rng = np.random.default_rng(0)
named = {name: np.asarray(params["layers"]["attn"][name][0], np.float32).T
         for name in ("wq", "wk", "wv")}
group = ESPIMGroupLinear.from_dense(named, prune_sparsity=SPARSITY)
x = rng.standard_normal(cfg.d_model).astype(np.float32)
ys = group(jnp.asarray(x), impl="ref")
for name, w in named.items():
    ref = magnitude_prune(w, SPARSITY) @ x
    print(f"  {name}: max err vs dense-pruned = "
          f"{np.abs(np.asarray(ys[name]) - ref).max():.2e} "
          f"(one launch for all of {'/'.join(group.names)})")

# --- production serving: paged cache + chunked prefill + scheduler ---------
# A mixed-length trace: short chat-like prompts interleaved with long ones.
# The shortest-prompt-first policy admits the short prompts ahead of the
# long ones (lower mean TTFT); chunked prefill turns each long prompt into
# ceil(len/chunk) jitted calls; all slots share one block-pool KV arena.
# ``--quant`` serves decode from int8/int4 value planes (section 9): same
# packs, same schedules, narrow codes + per-row-group scales.
# ``--sparse-attn`` compiles the fused QKV + O groups too (section 10) so
# decode runs EVERY per-token MV through the packed kernels.
proj = "all" if args.sparse_attn else "mlp"
sparse = sparsify_model(cfg, params, SPARSITY, projections=proj,
                        quant=QUANT)
st_all = sparse_stats(sparse)
st = st_all["total"]
if args.sparse_attn:
    # the delta the flag buys: whole-layer packed vs MLP-only (which still
    # streams every dense attention byte per decode token).  No second
    # packing pass: the MLP-only baseline is the gateup+down planes of
    # THIS pack plus the dense q/k/v/o bytes.
    attn_w = params["layers"]["attn"]
    attn_dense = sum(int(np.size(attn_w[n])) * attn_w[n].dtype.itemsize
                     for n in ("wq", "wk", "wv", "wo"))
    mlp_only = attn_dense + sum(
        st_all[g]["value_plane_bytes"] + st_all[g]["index_plane_bytes"]
        for g in ("gateup", "down"))
    print(f"\nsparse-attn: whole-model weight bytes/token "
          f"{mlp_only} (MLP packs + {attn_dense} dense attention bytes) "
          f"-> {st['bytes_per_token']} all-packed "
          f"({mlp_only / st['bytes_per_token']:.2f}x smaller)")
if QUANT != "none":
    # the fp baseline needs no second packing pass: fp32 values cost 4
    # bytes/slot — exactly the quant-invariant int32 index plane's size
    fp_bytes = (2 * st["index_plane_bytes"]
                + st["dense_proj_bytes_per_token"])
    fp_bits = 8.0 * st["index_plane_bytes"] / st["nnz"]
    print(f"\nquant={QUANT}: weight bytes/token "
          f"{fp_bytes} -> {st['bytes_per_token']} "
          f"({fp_bytes / st['bytes_per_token']:.2f}x smaller; value plane "
          f"{st['bits_per_nnz']:.1f} bits/nnz vs fp {fp_bits:.1f})")

# --- per-shape schedule autotuning (DESIGN.md section 15) ------------------
# Search the legal schedule space for the model's own layer-0 gate matrix
# (cost-ranked, top-k measured), then tune again: the second call must be
# a pure fingerprint-keyed cache hit — zero candidate benchmarks.
tuned_plan = None
if args.autotune:
    from repro.autotune import (PlanCache, autotune_pack,
                                reset_search_stats, search_stats)
    from repro.core.sparse_format import pack_ell

    w0 = magnitude_prune(
        np.asarray(params["layers"]["mlp"]["w_gate"][0], np.float32).T,
        SPARSITY)
    pack = pack_ell(w0)
    qmode = None if QUANT == "none" else QUANT
    plan_cache = PlanCache()
    reset_search_stats()
    tuned_plan = autotune_pack(pack, b=1, quant=qmode, cache=plan_cache)
    searched = dict(search_stats)
    cached_plan = autotune_pack(pack, b=1, quant=qmode, cache=plan_cache)
    p = tuned_plan.to_provenance()
    print(f"\nautotune ({w0.shape[0]}x{w0.shape[1]} gate matrix, "
          f"quant={QUANT}):")
    print(f"  searched: chunk_cols={p['chunk_cols']} block_r={p['block_r']} "
          f"block_l={p['block_l']} gather={p['gather']} "
          f"({p['candidates']} candidates measured, best "
          f"{p['best_us']:.1f}us, cache key {p['cache_key'][:12]}...)")
    print(f"  re-tuned: source={cached_plan.source} "
          f"({search_stats['benchmarks'] - searched['benchmarks']} "
          f"benchmarks — the warm plan cache skips the search entirely)")

prompt_lens = [3, 40, 2, 56, 5, 24, 4, 12]
prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
           for n in prompt_lens]

eng = ServeEngine(cfg, params, batch_slots=4, max_len=96, sparse=sparse,
                  paged=True, block_size=16, prefill_chunk=16,
                  policy="sjf", tracer=tracer)
reqs = [Request(rid=rid, prompt=p, max_new_tokens=12)
        for rid, p in enumerate(prompts)]
for r in reqs:
    eng.submit(r)
t0 = time.time()
stats = eng.run()
dt = time.time() - t0
lat = stats.latency_summary()
print(f"\nserved {stats.requests_completed} requests / "
      f"{stats.tokens_generated} tokens in {dt:.1f}s "
      f"({stats.tokens_generated / dt:.1f} tok/s on CPU; "
      f"{stats.prefill_chunks} prefill chunks + {stats.decode_steps} "
      f"decode steps, slot occupancy {stats.slot_occupancy:.0%})")
print(f"TTFT p50/p95 = {lat['ttft_s']['p50']:.3f}/"
      f"{lat['ttft_s']['p95']:.3f}s, "
      f"TPOT p50 = {lat['tpot_s']['p50'] * 1e3:.1f}ms, "
      f"queue delay p95 = {lat['queue_delay_s']['p95']:.3f}s "
      f"(sjf over {len(reqs)} mixed-length prompts, "
      f"arena {eng.cache.num_blocks} x {eng.cache.block_size}-token "
      f"blocks)")

if tuned_plan is not None:
    # serve the SAME trace again with the packs chunked under the tuned
    # schedule — the tok/s delta the search bought (identical tokens: a
    # schedule is a performance knob, never a semantics knob)
    sparse_t = sparsify_model(cfg, params, SPARSITY, projections=proj,
                              quant=QUANT,
                              chunk_cols=tuned_plan.schedule.chunk_cols)
    eng_t = ServeEngine(cfg, params, batch_slots=4, max_len=96,
                        sparse=sparse_t, paged=True, block_size=16,
                        prefill_chunk=16, policy="sjf")
    for rid, pr in enumerate(prompts):
        eng_t.submit(Request(rid=rid, prompt=pr, max_new_tokens=12))
    t0 = time.time()
    stats_t = eng_t.run()
    dt_t = time.time() - t0
    tok_s = stats.tokens_generated / dt
    tok_s_t = stats_t.tokens_generated / dt_t
    print(f"\nautotuned engine (chunk_cols="
          f"{tuned_plan.schedule.chunk_cols} vs default "
          f"{ops.DEFAULT_CHUNK_COLS}): {tok_s_t:.1f} tok/s vs "
          f"{tok_s:.1f} default "
          f"({(tok_s_t / max(tok_s, 1e-9) - 1) * 100:+.1f}%)")

if args.trace:
    prov = ops.provenance(impl=eng.impl, quant=QUANT,
                          attn="sparse" if args.sparse_attn else "dense")
    if args.trace.endswith(".jsonl"):
        tracer.write_jsonl(args.trace, provenance=prov)
    else:
        tracer.write_chrome_trace(args.trace, provenance=prov)
    bd = phase_breakdown(tracer, parent="engine.step")
    phases = ", ".join(f"{k} {v['frac']:.0%}"
                       for k, v in sorted(bd["phases"].items(),
                                          key=lambda kv: -kv[1]["frac"]))
    print(f"\ntrace: {len(tracer.spans())} spans -> {args.trace} "
          f"(open at https://ui.perfetto.dev)\n"
          f"engine.step breakdown ({bd['coverage']:.0%} of "
          f"{bd['wall_us'] / 1e3:.1f}ms step wall): {phases}")
    # per-request timelines (DESIGN.md §14): the same trace, folded into
    # one lifecycle strip per request — q=queued, p=prefill, d=decode,
    # .=resident-but-waiting
    print("\nper-request timelines:")
    tls = timelines_from_tracer(tracer)
    for rid in sorted(tls):
        print(format_timeline(tls[rid]))
