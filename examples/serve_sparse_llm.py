"""The paper's deployment scenario: serve an LM whose projections were
magnitude-pruned and packed into the ESPIM format, with batched continuous
decoding, and compare the sparse projections' outputs against the
dense-pruned reference.

Run:  PYTHONPATH=src python examples/serve_sparse_llm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.espim_linear import ESPIMLinear
from repro.core.pruning import magnitude_prune
from repro.models import factory
from repro.serve.engine import Request, ServeEngine

SPARSITY = 0.9

cfg = get_config("llama7b-espim", reduced=True)
params = factory.init_params(cfg, jax.random.PRNGKey(0))

# --- flexible dense/sparse projections (Section III-I) ---------------------
# Pack every attention projection of layer 0 through ESPIMLinear and verify
# against the dense-pruned reference.
print(f"packing layer-0 projections at {SPARSITY:.0%} sparsity:")
rng = np.random.default_rng(0)
for name in ("wq", "wk", "wv", "wo"):
    w = np.asarray(params["layers"]["attn"][name][0], np.float32).T
    lin = ESPIMLinear.from_dense(w, prune_sparsity=SPARSITY)
    x = rng.standard_normal(w.shape[1]).astype(np.float32)
    y = np.asarray(lin(jnp.asarray(x), impl="ref"))
    ref = magnitude_prune(w, SPARSITY) @ x
    print(f"  {name}: sparse path={lin.sparse}, "
          f"max err vs dense-pruned = {np.abs(y - ref).max():.2e}")

# --- batched serving --------------------------------------------------------
eng = ServeEngine(cfg, params, batch_slots=4, max_len=96)
prompts = [[1, 5, 9], [2, 4], [7, 7, 7, 7], [3], [8, 1], [6, 2, 4]]
for rid, p in enumerate(prompts):
    eng.submit(Request(rid=rid, prompt=p, max_new_tokens=12))
t0 = time.time()
stats = eng.run()
dt = time.time() - t0
print(f"\nserved {stats.requests_completed} requests / "
      f"{stats.tokens_generated} tokens in {dt:.1f}s "
      f"({stats.tokens_generated / dt:.1f} tok/s on CPU, "
      f"{stats.steps} engine steps, continuous batching over 4 slots)")
