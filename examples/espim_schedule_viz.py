"""Visualize what SDDS actually schedules: the command mix, stall sources,
and how each optimization changes the slot count — an ASCII rendition of
the paper's Figure 11 story on one matrix.

Run:  PYTHONPATH=src python examples/espim_schedule_viz.py
"""
import numpy as np

from repro.core.pim_sim import espim_cycles, simulate_matrix
from repro.core.pruning import magnitude_prune
from repro.core.sdds import ESPIMConfig, schedule_matrix

rng = np.random.default_rng(0)
W = magnitude_prune(rng.standard_normal((352, 2048)), 0.9)
print(f"matrix 352x2048 @ 90% sparsity, nnz={int((W != 0).sum())}\n")

STEPS = [
    ("fine-grained base", dict(prefetch=False, reorder=False, balance=False)),
    ("+ decoupled prefetch", dict(reorder=False, balance=False)),
    ("+ switch reorder", dict(balance=False)),
    ("+ greedy balance", dict()),
    ("(16x11 brute switch)", dict(full_switch=True)),
]

base = None
print(f"{'configuration':24s} {'slots':>7s} {'br':>6s} {'stall':>6s} "
      f"{'dummy':>7s} {'cycles':>8s}  speedup   bar")
for name, kw in STEPS:
    cfg = ESPIMConfig(**kw)
    sched, _ = schedule_matrix(W, cfg)
    cyc = espim_cycles(sched, cfg).cycles
    if base is None:
        base = cyc
    bar = "#" * int(40 * cyc / base)
    print(f"{name:24s} {sched.compute_slots:7d} {sched.comp_br:6d} "
          f"{sched.comp_nobr:6d} {sched.dummy_cells:7d} {cyc:8.0f}  "
          f"{base / cyc:6.2f}x   {bar}")

print("\ncommand mix of the full configuration:")
cfg = ESPIMConfig()
sched, _ = schedule_matrix(W, cfg)
total = sched.column_reads
for cmd, n in (("COMP-BR (broadcast)", sched.comp_br),
               ("COMP-NoBR (stall)", sched.comp_nobr),
               ("LOAD-IDX (prefetch)", sched.load_idx)):
    print(f"  {cmd:22s} {n:6d}  {'#' * int(50 * n / total)}")
mac_slots = sched.compute_slots * cfg.n_banks * cfg.macs_per_bank
print(f"  MAC occupancy: {sched.mac_ops}/{mac_slots} slots = "
      f"{sched.mac_ops / mac_slots:.1%} "
      f"(dummy cells are the paper's statically scheduled bubbles)")

reps = simulate_matrix(W, cfg, archs=("espim", "newton"))
print(f"\nvs Newton: {reps['newton'].cycles / reps['espim'].cycles:.2f}x "
      f"speedup at 90% sparsity")
