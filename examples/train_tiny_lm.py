"""End-to-end training driver: train a ~25M-param granite-family model for
a few hundred steps on CPU with checkpointing + exact resume.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse

import jax

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # ~25M params: granite family widened a bit beyond the smoke config
    cfg = get_config("granite-3-2b", reduced=True).replace(
        n_layers=6, d_model=384, n_heads=6, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab_size=4096)
    shape = ShapeConfig("tiny", seq_len=128, global_batch=8, kind="train")
    mesh = make_local_mesh()
    tr = Trainer(
        cfg, shape, mesh,
        OptConfig(peak_lr=3e-4, warmup_steps=30, decay_steps=args.steps),
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20),
    )
    kind, step = tr.init_or_resume()
    n_params = sum(x.size for x in jax.tree.leaves(tr.state["params"]))
    print(f"{kind} at step {step}; params={n_params/1e6:.1f}M")
    tr.train(args.steps - step)
    tr.save()
    print(f"final checkpoint at step {tr.step} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
