"""Quickstart: the ESPIM pipeline end to end on one weight matrix.

  prune -> SparTen balance + ELL pack (the fine-grained interleaving)
        -> Pallas sparse MV kernel (interpret mode on CPU)
        -> SDDS cycle-level schedule -> PIM cycles + energy vs Newton.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.energy import espim_energy, gpu_dram_energy, newton_energy
from repro.core.pim_sim import simulate_matrix
from repro.core.pruning import magnitude_prune
from repro.core.sdds import ESPIMConfig, schedule_matrix
from repro.core.sparse_format import pack_ell_chunked
from repro.kernels import ops

rng = np.random.default_rng(0)

# 1. a "trained" projection, magnitude-pruned to 90% (Section IV)
w = magnitude_prune(rng.standard_normal((512, 2048)).astype(np.float32), 0.9)
x = rng.standard_normal(2048).astype(np.float32)
print(f"weight 512x2048, sparsity={(w == 0).mean():.2f}")

# 2. offline packing (the TPU-side SDDS analogue): column-chunked ELL —
#    each (row-tile x chunk) kernel block reads one 512-wide slab of x
pack = pack_ell_chunked(w, chunk_cols=512)
print(f"packed: {pack.n_chunks} chunks x Lc={pack.chunk_width}, "
      f"padding(frac of slots acting as SDDS stalls)="
      f"{pack.stats.padding_frac:.2f}, x VMEM per step "
      f"{pack.plan.x_bytes_per_step}B (full would be "
      f"{pack.plan.x_bytes_full}B)")

# 3. sparse MV through the Pallas kernel, checked against dense
dev = ops.pack_to_device(pack)
y = ops.espim_matvec(dev, jnp.asarray(x))
err = np.abs(np.asarray(y) - w @ x).max()
print(f"espim_spmv vs dense matmul: max err {err:.2e}")

# 4. the paper's machine: SDDS schedule + cycle simulation vs Newton
cfg = ESPIMConfig()
sched, yv = schedule_matrix(w, cfg, values=w, x=x.astype(np.float64),
                            verify=True)
print(f"SDDS: {sched.compute_slots} column slots "
      f"({sched.comp_br} broadcasts, {sched.comp_nobr} stalls, "
      f"{sched.load_idx} LOAD-IDX), dataflow err "
      f"{np.abs(yv - w @ x.astype(np.float64)).max():.2e}")

reps = simulate_matrix(w, cfg, archs=("espim", "newton", "ideal_nonpim"))
print(f"cycles: espim={reps['espim'].cycles:.0f} "
      f"newton={reps['newton'].cycles:.0f} "
      f"-> {reps['newton'].cycles / reps['espim'].cycles:.2f}x speedup")

base = gpu_dram_energy(*w.shape).total
ee = espim_energy(sched).normalized(base)
en = newton_energy(w.shape[0], w.shape[1], int((w != 0).sum())
                   ).normalized(base)
print(f"energy vs conventional DRAM: espim={ee.total:.2f}x "
      f"newton={en.total:.2f}x ({(1 - ee.total / en.total) * 100:.0f}% saved)")
