#!/usr/bin/env bash
# One reproducible entrypoint: install deps, run the decode-path smoke
# microbench FIRST (single fused layer, tiny shapes, parity-asserted in
# fp AND from the quantized int8/int4 value planes, AND a whole-layer
# attention-sparse decode step — fused QKV + O pack groups vs dense over
# the pruned copies — so a kernel-, quant- or pack-group regression
# fails here in seconds, long before the full serve bench), then the
# serving fault-drill smoke (every fault class rejected at load or
# recovered with zero leaks — the robustness gate; traced, so the drill
# emits a validated span trace too), then the crash-recovery drill
# (snapshot/restore with bit-exact parity and zero leaked blocks) and
# the overload smoke (Poisson burst at 2x capacity absorbed by
# shed/preempt policy, goodput-under-SLO reported, no OOM) — both gate
# ahead of the tests so a robustness regression fails in seconds — then
# tier-1 tests, then the serving
# benchmark smoke (traced: the telemetry gate validates the Chrome
# trace_event schema, >= 95% engine.step span coverage, and the metrics
# snapshot against the checked-in REQUIRED_SERVE_METRICS family list).
#
#   scripts/ci.sh                  # smoke benches + tests
#   FULL_BENCH=1 scripts/ci.sh     # also regenerate the full BENCH_kernels.json
#   SKIP_INSTALL=1 scripts/ci.sh   # images with deps baked in
set -euo pipefail
cd "$(dirname "$0")/.."

if [ -z "${SKIP_INSTALL:-}" ]; then
    # best-effort: pre-baked images (or offline hosts) run with what they have
    python -m pip install -r requirements.txt || \
        echo "WARN: pip install failed; continuing with installed packages"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== decode-path smoke microbench: fp + quant int8/int4 + attention-sparse fused layer (fail fast) =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" ESPIM_IMPL=ref \
    python benchmarks/kernels_bench.py --smoke

echo "== serving fault-drill smoke: bit flips rejected at load, quarantine->dense, cancel/OOM/retry recovery =="
rm -f FLIGHT_quarantine.json
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" ESPIM_IMPL=ref \
    python benchmarks/serve_bench.py --fault-drill --smoke \
    --out BENCH_fault_drill_smoke.json --trace TRACE_fault_drill_smoke.json
test -f BENCH_fault_drill_smoke.json && echo "BENCH_fault_drill_smoke.json written"
# the drill's nonfinite quarantine must auto-dump the flight ring — the
# always-on post-mortem contract (DESIGN.md §14)
test -f FLIGHT_quarantine.json && echo "FLIGHT_quarantine.json written (flight recorder auto-dump)"

echo "== crash-recovery drill: kill at an arbitrary step, restore, bit-exact parity + zero leaks =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" ESPIM_IMPL=ref \
    python benchmarks/serve_bench.py --crash-drill --smoke \
    --out BENCH_crash_drill_smoke.json
test -f BENCH_crash_drill_smoke.json && echo "BENCH_crash_drill_smoke.json written"

echo "== overload smoke: Poisson burst at 2x capacity, shed/preempt per policy, no OOM =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" ESPIM_IMPL=ref \
    python benchmarks/serve_bench.py --overload --smoke \
    --out BENCH_overload_smoke.json
test -f BENCH_overload_smoke.json && echo "BENCH_overload_smoke.json written"

echo "== autotune smoke: budgeted search, warm cache hit, fused-epilogue parity =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" ESPIM_IMPL=ref python - <<'EOF'
import os, tempfile

import jax, numpy as np, jax.numpy as jnp

from repro.autotune import PlanCache, autotune_pack, reset_search_stats, \
    search_stats
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import chunk_pack, pack_ell
from repro.kernels import ops
from repro.models.layers import act_fn

# budgeted search (<= 2 candidate benchmarks per shape), persisted cache
rng = np.random.default_rng(0)
cache = PlanCache(os.path.join(tempfile.mkdtemp(), "plans.json"))
plans = {}
for name, r, c in (("wq", 256, 256), ("w2", 128, 512)):
    w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), 0.9)
    reset_search_stats()
    plans[name] = autotune_pack(pack_ell(w), b=4, cache=cache,
                                max_candidates=2, iters=1, warmup=0)
    assert plans[name].source == "search"
    assert search_stats["benchmarks"] <= 2, search_stats
# second invocation must be 100% cache hit: zero candidate benchmarks —
# rebuild the identical packs from the same seed (the cache key is
# content-addressed, so same bytes -> same key)
reset_search_stats()
rng = np.random.default_rng(0)
for name, r, c in (("wq", 256, 256), ("w2", 128, 512)):
    w = magnitude_prune(rng.standard_normal((r, c)).astype(np.float32), 0.9)
    p = autotune_pack(pack_ell(w), b=4, cache=cache, max_candidates=2,
                      iters=1, warmup=0)
    assert p.source == "cache", (name, p.source)
    assert p.schedule == plans[name].schedule
assert search_stats["benchmarks"] == 0, \
    f"warm cache ran {search_stats['benchmarks']} benchmarks"

# fused GLU epilogue bit-identical to the unfused reference (fp + int4)
w = magnitude_prune(rng.standard_normal((128, 256)).astype(np.float32), 0.9)
cp = chunk_pack(pack_ell(w), 128)
v, cl = jnp.asarray(cp.values), jnp.asarray(cp.cols, jnp.int32)
x = jnp.asarray(rng.standard_normal((256, 4)), jnp.float32)
acc = ops.espim_spmv_batched(v, cl, x, chunk_cols=128, impl="ref")
want = act_fn("silu")(acc[:64]) * acc[64:]
got = ops.espim_spmv_batched(v, cl, x, chunk_cols=128, impl="ref",
                             epilogue="glu")
assert (np.asarray(got) == np.asarray(want)).all(), "fp GLU fusion diverged"
from repro.quant import default_spec, quantize_pack
plane = quantize_pack(cp, default_spec("int4"))
codes = jnp.asarray(plane.device_codes())
srow = jnp.asarray(plane.row_scales().astype(np.float32))
acc_q = ops.espim_spmv_batched_quant(codes, cl, None, x, chunk_cols=128,
                                     group_rows=plane.group_rows,
                                     impl="ref") * srow[:, None]
want_q = act_fn("silu")(acc_q[:64]) * acc_q[64:]
got_q = ops.espim_spmv_batched_quant(codes, cl, None, x, chunk_cols=128,
                                     group_rows=plane.group_rows, impl="ref",
                                     epilogue="glu", srow=srow)
assert (np.asarray(got_q) == np.asarray(want_q)).all(), \
    "int4 GLU fusion diverged"
print("autotune smoke ok: budgeted search (<=2 benches/shape), second "
      "invocation 100% cache hit (0 benchmarks), GLU epilogue bit-exact "
      "fp+int4")
EOF

echo "== tier-1 tests =="
python -m pytest -x -q

if [ -n "${FULL_BENCH:-}" ]; then
    echo "== full kernel benchmark =="
    PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/kernels_bench.py
    test -f BENCH_kernels.json && echo "BENCH_kernels.json written"
fi

echo "== serving benchmark smoke (traced) =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/serve_bench.py \
    --smoke --out BENCH_serve_smoke.json --trace TRACE_serve_smoke.json
test -f BENCH_serve_smoke.json && echo "BENCH_serve_smoke.json written"

echo "== telemetry smoke: trace_event schema + span coverage + metrics snapshot =="
python - <<'EOF'
import json

from repro.telemetry.trace import BREAKDOWN_SCHEMA_KEYS, validate_chrome_trace

for path in ("TRACE_serve_smoke.json", "TRACE_fault_drill_smoke.json"):
    doc = json.load(open(path))
    validate_chrome_trace(doc)
    assert doc["otherData"]["provenance"]["impl"], path
    names = {e["name"] for e in doc["traceEvents"]}
    assert "engine.step" in names, f"{path}: no engine.step spans"
    print(f"{path}: {len(doc['traceEvents'])} events, schema valid")

bench = json.load(open("BENCH_serve_smoke.json"))
tel = bench["telemetry"]
assert all(k in tel["breakdown"] for k in BREAKDOWN_SCHEMA_KEYS)
assert tel["step_coverage"] >= 0.95, tel["step_coverage"]
assert tel["overlap_errors"] == 0
# the snapshot was validated against REQUIRED_SERVE_METRICS inside the
# bench (validate_snapshot); re-assert the family list is intact here
from repro.telemetry.metrics import REQUIRED_SERVE_METRICS
missing = [m for m in REQUIRED_SERVE_METRICS
           if m not in tel["metrics_families"]]
assert not missing, f"metrics families missing from traced run: {missing}"
# per-request timelines (PR 9): the traced smoke must reconstruct a
# complete lifecycle for 100% of terminal requests — from the bench's
# own check AND independently from the exported trace artifact
tl = tel["timelines"]
assert tl["requests"] > 0 and tl["complete"] == tl["requests"], tl
from repro.telemetry.timeline import timelines_from_chrome
trace_doc = json.load(open("TRACE_serve_smoke.json"))
tls = timelines_from_chrome(trace_doc)
assert len(tls) == tl["requests"] and all(
    t.complete for t in tls.values()), \
    f"chrome-trace timeline reconstruction incomplete: {tls}"
print(f"telemetry smoke ok: step coverage {tel['step_coverage']:.1%}, "
      f"{len(tel['metrics_families'])} metric families, "
      f"{tl['complete']}/{tl['requests']} request timelines complete "
      f"(max ttft err {tl['max_ttft_err_s']}s)")
EOF

echo "== perf-regression sentinel: both smokes vs checked-in baselines =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/bench_history.py check \
    --bench BENCH_kernels_smoke.json --baseline benchmarks/baselines/kernels_smoke.json
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/bench_history.py check \
    --bench BENCH_serve_smoke.json --baseline benchmarks/baselines/serve_smoke.json

echo "== sentinel negative check: a 10x-perturbed metric must fail loudly =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json, subprocess, sys, tempfile

doc = json.load(open("BENCH_serve_smoke.json"))
m = doc["scenarios"]["single_stream"]["modes"]["sparse"]
m["throughput_tok_s"] /= 10.0          # simulate an order-of-magnitude cliff
m["throughput_p50_tok_s"] /= 10.0
with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
    json.dump(doc, f)
    bad = f.name
r = subprocess.run(
    [sys.executable, "benchmarks/bench_history.py", "check",
     "--bench", bad, "--baseline", "benchmarks/baselines/serve_smoke.json"],
    capture_output=True, text=True)
assert r.returncode != 0, "sentinel PASSED a 10x throughput regression"
assert "single_stream.sparse.tok_s" in r.stderr, r.stderr
print("sentinel negative check ok: 10x perturbation rejected with "
      "offending metric, baseline window, and observed value in the log")
EOF

echo "== bench trajectory =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py summary
