#!/usr/bin/env bash
# One reproducible entrypoint: install deps, run tier-1 tests, then the
# kernel benchmark smoke (emits BENCH_kernels.json) and the serving
# benchmark smoke (tiny trace, asserts the BENCH_serve.json schema).
#
#   scripts/ci.sh            # full run
#   SKIP_INSTALL=1 scripts/ci.sh   # images with deps baked in
set -euo pipefail
cd "$(dirname "$0")/.."

if [ -z "${SKIP_INSTALL:-}" ]; then
    # best-effort: pre-baked images (or offline hosts) run with what they have
    python -m pip install -r requirements.txt || \
        echo "WARN: pip install failed; continuing with installed packages"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernel benchmark smoke =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/kernels_bench.py
test -f BENCH_kernels.json && echo "BENCH_kernels.json written"

echo "== serving benchmark smoke =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/serve_bench.py \
    --smoke --out BENCH_serve_smoke.json
test -f BENCH_serve_smoke.json && echo "BENCH_serve_smoke.json written"
